"""Overload-hardening tests: bounded admission with priority-ordered
shedding, QueueClosed/QueueFull semantics (incl. a close/drain race),
per-request cancellation/timeout with sibling PRNG bit-identity, dispatch
fault isolation with bounded backoff, the batch-path failure re-queue
regression, and per-class latency/SLO accounting in stream_report."""

import threading

import numpy as np
import pytest

from repro.serving import (
    ACCEPTED_DRAFT, CANCELLED, COMPLETED, DISTILLED, FAILED, SHED, TIMED_OUT,
    AdmissionQueue,
    CancelToken, DispatchFailure, DispatchRetryPolicy, FillingBucket,
    QueueClosed, QueueFull, ServeRequest, WarmStartScheduler, priority_rank,
    uniform_draft,
)

from test_streaming import FakeClock, ToyFlow, make_scheduler


class RecordingClock(FakeClock):
    """FakeClock that also records every sleep duration."""

    def __init__(self, t0=0.0):
        super().__init__(t0)
        self.sleeps = []

    def sleep(self, dt):
        self.sleeps.append(dt)
        super().sleep(dt)


# ---------------------------------------------------------------------------
# bounded admission: shed order, rejection, ledger
# ---------------------------------------------------------------------------

def test_bounded_queue_sheds_lowest_class_newest_first():
    q = AdmissionQueue(max_depth=3)
    a = q.submit(seq_len=8, priority="best_effort")
    b = q.submit(seq_len=8, priority="standard")
    c = q.submit(seq_len=8, priority="best_effort")
    # full; premium evicts the NEWEST best_effort request (c, not a)
    d = q.submit(seq_len=8, priority="premium")
    assert [r.request_id for r in q.take_shed()] == [c]
    # full again; standard evicts the remaining best_effort
    e = q.submit(seq_len=8, priority="standard")
    assert [r.request_id for r in q.take_shed()] == [a]
    stats = q.stats()
    assert stats == {"offered": 5, "accepted": 5, "rejected": 0, "shed": 2,
                     "shed_by_class": {"best_effort": 2}, "max_depth": 3}
    assert [r.request_id for r in q.drain()] == [b, d, e]


def test_bounded_queue_never_sheds_equal_or_higher_class():
    q = AdmissionQueue(max_depth=2)
    q.submit(seq_len=8, priority="premium")
    q.submit(seq_len=8, priority="standard")
    # equal class present (standard) -> reject, don't shed
    with pytest.raises(QueueFull):
        q.submit(seq_len=8, priority="standard")
    # lower class incoming -> reject; premium/standard are never shed
    # to admit best_effort
    with pytest.raises(QueueFull):
        q.submit(seq_len=8, priority="best_effort")
    stats = q.stats()
    assert stats["offered"] == 4
    assert stats["accepted"] == 2 and stats["rejected"] == 2
    assert stats["shed"] == 0
    assert len(q) == 2


def test_submit_after_close_raises_queue_closed():
    q = AdmissionQueue()
    q.submit(seq_len=8)
    q.close()
    with pytest.raises(QueueClosed):
        q.submit(seq_len=8)
    with pytest.raises(QueueClosed):
        q.push(ServeRequest(request_id=99, seq_len=8))
    # QueueClosed is a ValueError so pre-existing handlers keep working
    with pytest.raises(ValueError):
        q.submit(seq_len=8)
    assert len(q.drain()) == 1


def test_close_drain_race_loses_no_accepted_request():
    """Producers hammering submit() while the queue closes: every offer
    either lands in drain(), is shed, or raised QueueClosed/QueueFull —
    the ledger balances exactly, nothing is silently dropped."""
    q = AdmissionQueue(max_depth=16)
    outcomes = {"accepted": 0, "closed": 0, "full": 0}
    lock = threading.Lock()

    def produce(k):
        for i in range(50):
            try:
                q.submit(seq_len=8, seed=k * 100 + i,
                         priority="best_effort" if i % 2 else "standard")
            except QueueClosed:
                with lock:
                    outcomes["closed"] += 1
            except QueueFull:
                with lock:
                    outcomes["full"] += 1
            else:
                with lock:
                    outcomes["accepted"] += 1

    threads = [threading.Thread(target=produce, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    q.close()
    for t in threads:
        t.join()
    drained = q.drain()
    shed = q.take_shed()
    stats = q.stats()
    assert stats["offered"] == sum(outcomes.values())
    assert stats["accepted"] == outcomes["accepted"]
    assert stats["rejected"] == outcomes["full"]
    # conservation: every accepted request is drained or shed exactly once
    assert len(drained) + len(shed) == outcomes["accepted"]
    assert len(shed) == stats["shed"]
    assert q.closed


def test_cancel_by_request_id():
    q = AdmissionQueue()
    rid = q.submit(seq_len=8)
    assert q.cancel(rid) is True
    assert q.cancel(12345) is False
    (req,) = q.drain()
    assert req.cancelled


# ---------------------------------------------------------------------------
# shedding through the stream: SHED terminal results + conservation
# ---------------------------------------------------------------------------

def test_stream_surfaces_shed_requests_and_balances_conservation():
    clock = FakeClock()
    q = AdmissionQueue(max_depth=2, clock=clock)
    q.submit(seq_len=8, seed=1, priority="best_effort")
    q.submit(seq_len=8, seed=2, priority="best_effort")
    kept = q.submit(seq_len=8, seed=3, priority="premium")   # sheds seed=2
    q.close()
    sched = make_scheduler(max_rows=16)
    out = list(sched.serve_stream(source=q, clock=clock))
    by_status = {}
    for c in out:
        by_status.setdefault(c.status, []).append(c)
    assert len(by_status[SHED]) == 1
    assert by_status[SHED][0].priority == "best_effort"
    assert by_status[SHED][0].tokens.shape == (0, 8)
    assert {c.request_id for c in by_status[COMPLETED]} == {0, kept}
    rep = sched.stream_report
    assert rep["terminal"] == {COMPLETED: 2, ACCEPTED_DRAFT: 0, DISTILLED: 0,
                               CANCELLED: 0, TIMED_OUT: 0, SHED: 1, FAILED: 0}
    assert rep["admission"]["shed_by_class"] == {"best_effort": 1}
    assert rep["conservation"]["balanced"]
    assert rep["by_class"]["best_effort"]["shed"] == 1
    assert rep["by_class"]["premium"]["completed"] == 1


# ---------------------------------------------------------------------------
# cancellation / timeout: terminal statuses + sibling bit-identity
# ---------------------------------------------------------------------------

def _serve_ids(reqs, **kw):
    sched = make_scheduler(max_rows=16)
    return {c.request_id: c for c in sched.serve_stream(reqs, **kw)}, sched


def test_cancel_while_queued_frees_rows_and_keeps_siblings_bit_identical():
    reqs = [ServeRequest(request_id=i, seq_len=8, num_samples=2, seed=50 + i,
                         cancel_token=CancelToken()) for i in range(4)]
    baseline, _ = _serve_ids([r for r in reqs if r.request_id != 2])
    reqs[2].cancel_token.cancel()
    got, sched = _serve_ids(reqs)
    assert got[2].status == CANCELLED
    assert got[2].tokens.shape == (0, 8)
    for rid in (0, 1, 3):
        assert got[rid].status == COMPLETED
        np.testing.assert_array_equal(got[rid].tokens, baseline[rid].tokens)
    rep = sched.stream_report
    assert rep["terminal"][CANCELLED] == 1
    assert rep["conservation"]["balanced"]


def test_cancel_in_filling_bucket_via_queue_cancel():
    clock = FakeClock()
    q = AdmissionQueue(clock=clock)
    keep = q.submit(seq_len=8, seed=1)
    dead = q.submit(seq_len=8, seed=2)
    assert q.cancel(dead)
    q.close()
    baseline, _ = _serve_ids(
        [ServeRequest(request_id=keep, seq_len=8, seed=1)])
    sched = make_scheduler(max_rows=16)
    out = {c.request_id: c for c in sched.serve_stream(source=q, clock=clock)}
    assert out[dead].status == CANCELLED
    assert out[keep].status == COMPLETED
    np.testing.assert_array_equal(out[keep].tokens, baseline[keep].tokens)


def test_cancel_after_packing_masks_row_out_of_micro_batch():
    """Cancel lands AFTER the micro-batch is packed and drafted (injected
    right before the refine dispatch): the request's computed rows are
    discarded, it resolves CANCELLED, and every sibling's tokens are
    bit-identical to a run where it was never submitted — the
    pack-invariance contract extended to mid-flight cancellation."""
    reqs = [ServeRequest(request_id=i, seq_len=8, num_samples=2, seed=70 + i,
                         cancel_token=CancelToken()) for i in range(3)]
    baseline, _ = _serve_ids([r for r in reqs if r.request_id != 1])
    sched = make_scheduler(max_rows=16)
    sched._dispatch_fault_hook = \
        lambda mb, attempt: reqs[1].cancel_token.cancel()
    got = {c.request_id: c for c in sched.serve_stream(reqs)}
    assert got[1].status == CANCELLED
    for rid in (0, 2):
        np.testing.assert_array_equal(got[rid].tokens, baseline[rid].tokens)
    rep = sched.stream_report
    assert rep["terminal"] == {COMPLETED: 2, ACCEPTED_DRAFT: 0, DISTILLED: 0,
                               CANCELLED: 1, TIMED_OUT: 0, SHED: 0, FAILED: 0}
    assert rep["conservation"]["balanced"]


def test_timeout_in_filling_bucket_resolves_timed_out():
    clock = FakeClock()
    q = AdmissionQueue(clock=clock)
    q.submit(seq_len=8, seed=1, timeout_s=0.01)
    keep = q.submit(seq_len=8, seed=2)
    sched = make_scheduler(max_rows=16)
    stream = sched.serve_stream(source=q, idle_timeout_s=0.05, clock=clock)
    # queue stays open: the bucket waits on the idle timer while the
    # fake clock ticks past the request's 10ms budget -> pruned
    first = next(stream)
    assert first.status == TIMED_OUT and first.request_id == 0
    q.close()
    rest = list(stream)
    assert [c.request_id for c in rest] == [keep]
    assert rest[0].status == COMPLETED
    baseline, _ = _serve_ids(
        [ServeRequest(request_id=keep, seq_len=8, seed=2)])
    np.testing.assert_array_equal(rest[0].tokens, baseline[keep].tokens)
    assert sched.stream_report["terminal"][TIMED_OUT] == 1


def test_timeout_after_packing_masks_completed_rows():
    clock = FakeClock()
    reqs = [ServeRequest(request_id=0, seq_len=8, seed=5, timeout_s=0.5,
                         arrival_s=clock.time() + 1e-9),
            ServeRequest(request_id=1, seq_len=8, seed=6)]
    sched = make_scheduler(max_rows=16)
    # the dispatch "takes" 1s of fake time -> request 0 finishes past its
    # budget and is masked out at completion
    sched._dispatch_fault_hook = lambda mb, attempt: clock.sleep(1.0)
    got = {c.request_id: c
           for c in sched.serve_stream(reqs, clock=clock)}
    assert got[0].status == TIMED_OUT
    assert got[1].status == COMPLETED
    baseline, _ = _serve_ids([ServeRequest(request_id=1, seq_len=8, seed=6)])
    np.testing.assert_array_equal(got[1].tokens, baseline[1].tokens)


# ---------------------------------------------------------------------------
# priority classes: bucket separation, dispatch order, per-class report
# ---------------------------------------------------------------------------

def test_priority_rank_ordering():
    assert priority_rank("premium") < priority_rank("standard") \
        < priority_rank("best_effort")
    with pytest.raises(ValueError):
        priority_rank("platinum")


def test_premium_micro_batches_dispatch_before_best_effort():
    clock = FakeClock()
    q = AdmissionQueue(clock=clock)
    be = q.submit(seq_len=8, seed=1, priority="best_effort")
    pr = q.submit(seq_len=8, seed=2, priority="premium")
    q.close()
    sched = make_scheduler(max_rows=16)
    out = {c.request_id: c for c in sched.serve_stream(source=q, clock=clock)}
    # classes never share a micro-batch, and premium refines first even
    # though best_effort arrived first
    assert out[pr].micro_batch < out[be].micro_batch
    assert out[pr].priority == "premium"
    assert sched.stream_report["num_micro_batches"] == 2


def test_per_class_latency_and_slo_sections():
    reqs = [
        ServeRequest(request_id=0, seq_len=8, seed=1, priority="premium"),
        ServeRequest(request_id=1, seq_len=8, seed=2, priority="premium"),
        ServeRequest(request_id=2, seq_len=8, seed=3, priority="standard"),
        ServeRequest(request_id=3, seq_len=8, seed=4, priority="best_effort"),
    ]
    sched = make_scheduler(max_rows=16)
    out = list(sched.serve_stream(reqs, slo_ms=1e7))
    assert all(c.status == COMPLETED for c in out)
    rep = sched.stream_report
    by_cls = rep["by_class"]
    assert by_cls["premium"]["completed"] == 2
    assert by_cls["premium"]["slo_attainment"] == 1.0
    lat = by_cls["premium"]["latency_ms"]
    assert lat["n"] == 2 and lat["p50"] <= lat["p95"] <= lat["p99"]
    # best_effort has no deadline (class factor None): excluded from
    # attainment, still measured
    assert by_cls["best_effort"]["slo_attainment"] is None
    assert by_cls["best_effort"]["latency_ms"]["n"] == 1
    # and the best_effort request never armed a deadline
    be = [c for c in out if c.priority == "best_effort"]
    assert be[0].deadline_s is None and be[0].slo_met is None


# ---------------------------------------------------------------------------
# dispatch fault isolation: bounded backoff, FAILED containment
# ---------------------------------------------------------------------------

def test_transient_fault_retries_with_backoff_and_serves_bit_identical():
    reqs = [ServeRequest(request_id=i, seq_len=8, seed=90 + i)
            for i in range(2)]
    baseline, _ = _serve_ids(reqs)
    clock = RecordingClock()
    sched = make_scheduler(
        max_rows=16,
        retry_policy=DispatchRetryPolicy(max_retries=2, backoff_base_s=0.07))
    attempts = []

    def hook(mb, attempt):
        attempts.append(attempt)
        if attempt == 0:
            raise RuntimeError("transient device fault")

    sched._dispatch_fault_hook = hook
    got = {c.request_id: c for c in sched.serve_stream(reqs, clock=clock)}
    assert attempts == [0, 1]
    assert 0.07 in clock.sleeps          # backoff slept on the stream clock
    for rid, c in got.items():
        assert c.status == COMPLETED
        np.testing.assert_array_equal(c.tokens, baseline[rid].tokens)
    rep = sched.stream_report
    assert rep["dispatch"]["retries"] == 1
    assert rep["dispatch"]["failed_micro_batches"] == 0
    assert rep["terminal"][FAILED] == 0


def test_persistent_fault_fails_only_affected_micro_batch():
    # two buckets -> two micro-batches; the 32-bucket one always faults
    reqs = [ServeRequest(request_id=0, seq_len=8, seed=1),
            ServeRequest(request_id=1, seq_len=30, seed=2),
            ServeRequest(request_id=2, seq_len=8, seed=3)]
    baseline, _ = _serve_ids([reqs[0], reqs[2]])
    sched = make_scheduler(
        max_rows=16,
        retry_policy=DispatchRetryPolicy(max_retries=1, backoff_base_s=0.01))
    clock = RecordingClock()

    def hook(mb, attempt):
        if mb.bucket_len == 32:
            raise RuntimeError("persistent fault")

    sched._dispatch_fault_hook = hook
    got = {c.request_id: c for c in sched.serve_stream(reqs, clock=clock)}
    assert got[1].status == FAILED
    assert got[1].tokens.shape == (0, 30)
    for rid in (0, 2):
        assert got[rid].status == COMPLETED
        np.testing.assert_array_equal(got[rid].tokens, baseline[rid].tokens)
    rep = sched.stream_report
    assert rep["dispatch"]["failed_micro_batches"] == 1
    assert rep["dispatch"]["failed_requests"] == 1
    assert rep["dispatch"]["retries"] == 1       # one retry, then give up
    assert rep["terminal"][FAILED] == 1
    assert rep["conservation"]["balanced"]
    assert rep["by_class"]["standard"]["failed"] == 1


def test_dispatch_retry_policy_validation_and_backoff_schedule():
    p = DispatchRetryPolicy(max_retries=3, backoff_base_s=0.05,
                            backoff_factor=2.0)
    assert p.attempts == 4
    assert [p.backoff_s(a) for a in range(3)] == [0.05, 0.1, 0.2]
    assert p.worst_case_backoff_s == pytest.approx(0.35)
    with pytest.raises(ValueError):
        DispatchRetryPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        DispatchRetryPolicy(backoff_factor=0.5)


# ---------------------------------------------------------------------------
# the batch-path failure re-queue (regression for the run() except path)
# ---------------------------------------------------------------------------

def test_batch_path_requeues_on_dispatch_failure_and_stays_retryable():
    sched = make_scheduler(retry_policy=DispatchRetryPolicy(max_retries=0))
    ids = [sched.submit(seq_len=8, seed=i) for i in range(3)]

    def boom(mb, attempt):
        raise RuntimeError("device fell over")

    sched._dispatch_fault_hook = boom
    with pytest.raises(DispatchFailure):
        sched.run()
    # every request is back in the queue, in order — none lost
    assert [r.request_id for r in sched._queue] == ids
    sched._dispatch_fault_hook = None
    results, _ = sched.run()
    assert set(results) == set(ids)
    clean, _ = make_scheduler().serve_requests(
        [ServeRequest(request_id=i, seq_len=8, seed=i) for i in range(3)])
    for rid in ids:
        np.testing.assert_array_equal(results[rid].tokens, clean[rid].tokens)


def test_batch_path_raising_refine_fn_leaves_queue_retryable():
    """The original scheduler re-queue contract, now under test: ANY
    exception out of serve_requests (not just DispatchFailure) restores
    the queue."""
    sched = make_scheduler()
    ids = [sched.submit(seq_len=8, seed=i) for i in range(2)]
    real = sched._stage_refine
    calls = {"n": 0}

    def flaky(mb, x, flow_keys):
        calls["n"] += 1
        raise ValueError("not even a dispatch error")

    sched._stage_refine = flaky
    with pytest.raises(ValueError, match="not even"):
        sched.run()
    assert calls["n"] == 1
    assert [r.request_id for r in sched._queue] == ids
    sched._stage_refine = real
    results, _ = sched.run()
    assert set(results) == set(ids)


# ---------------------------------------------------------------------------
# FillingBucket.prune unit coverage
# ---------------------------------------------------------------------------

def test_filling_bucket_prune_removes_cancelled_and_expired():
    fb = FillingBucket(8)
    tok = CancelToken()
    fb.add(ServeRequest(request_id=0, seq_len=8, arrival_s=0.0,
                        cancel_token=tok), deadline_s=5.0)
    fb.add(ServeRequest(request_id=1, seq_len=8, arrival_s=0.0,
                        timeout_s=0.5), deadline_s=6.0)
    fb.add(ServeRequest(request_id=2, seq_len=8, arrival_s=0.0),
           deadline_s=7.0)
    tok.cancel()
    removed = fb.prune(now=1.0)
    assert [(r.request_id, s) for r, s in removed] == [(0, CANCELLED),
                                                       (1, TIMED_OUT)]
    assert [r.request_id for r in fb.requests] == [2]
    # the surviving request keeps ITS deadline (flush order unchanged)
    assert fb.oldest_deadline_s == 7.0
    fb.flush()
    with pytest.raises(ValueError):
        fb.prune(now=2.0)
