#!/usr/bin/env python
"""Summarise (and optionally validate) a Chrome trace produced by
``repro.launch.serve --trace-out``.

Prints a per-stage time breakdown (track/span totals sorted by total
time) plus per-request flow-chain coverage. With ``--check`` the script
exits non-zero when the trace fails schema validation — every event must
be well-formed trace-event JSON and every admitted request must carry a
complete admission→terminal flow chain.

    PYTHONPATH=src python tools/trace_summary.py trace.json
    PYTHONPATH=src python tools/trace_summary.py trace.json --check \
        --expected-requests 6

Only needs the stdlib-only ``repro.obs`` package — no jax/numpy.
"""

from __future__ import annotations

import argparse
import sys

from repro.obs import load_trace, stage_breakdown, validate_trace


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace-event JSON file "
                                  "(from serve --trace-out)")
    ap.add_argument("--check", action="store_true",
                    help="validate the trace schema and flow chains; "
                         "exit 1 on any problem")
    ap.add_argument("--expected-requests", type=int, default=None,
                    help="with --check: require exactly this many "
                         "complete admission→terminal request chains")
    args = ap.parse_args()

    trace = load_trace(args.trace)
    events = trace.get("traceEvents", [])
    print(f"{args.trace}: {len(events)} events")

    rows = stage_breakdown(trace)
    if rows:
        print("\nper-stage time breakdown:")
        print(f"  {'track':>15s} {'span':<16s} {'count':>6s} "
              f"{'total_ms':>10s} {'mean_ms':>8s} {'max_ms':>8s}")
        for r in rows:
            print(f"  {r['track']:>15s} {r['name']:<16s} {r['count']:>6d} "
                  f"{r['total_ms']:>10.2f} {r['mean_ms']:>8.2f} "
                  f"{r['max_ms']:>8.2f}")
    else:
        print("\nno duration spans in trace")

    admitted = [e for e in events if e.get("name") == "request_admitted"]
    terminal = [e for e in events if e.get("name") == "request_terminal"]
    statuses = {}
    for e in terminal:
        st = (e.get("args") or {}).get("status", "?")
        statuses[st] = statuses.get(st, 0) + 1
    by_status = ", ".join(f"{k}={v}" for k, v in sorted(statuses.items()))
    print(f"\nrequest flow chains: {len(admitted)} admitted, "
          f"{len(terminal)} terminal ({by_status or 'none'})")

    if args.check:
        problems = validate_trace(trace,
                                  expected_requests=args.expected_requests)
        if problems:
            print(f"\nFAIL: {len(problems)} problem(s):", file=sys.stderr)
            for p in problems:
                print(f"  - {p}", file=sys.stderr)
            return 1
        print("check: OK (schema valid, all request chains complete)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
