#!/usr/bin/env python
"""Extract the README's executable quickstart snippet, so CI can run
exactly what the docs show (the snippet between the
``<!-- quickstart:begin -->`` / ``<!-- quickstart:end -->`` markers).

Run:  python tools/extract_readme_snippet.py README.md out.py
      PYTHONPATH=src python out.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

BEGIN = "<!-- quickstart:begin -->"
END = "<!-- quickstart:end -->"
FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def extract(readme: Path) -> str:
    text = readme.read_text(encoding="utf-8")
    try:
        region = text.split(BEGIN, 1)[1].split(END, 1)[0]
    except IndexError:
        raise SystemExit(f"{readme}: quickstart markers "
                         f"{BEGIN!r} / {END!r} not found")
    m = FENCE_RE.search(region)
    if m is None:
        raise SystemExit(f"{readme}: no ```python fence between the "
                         f"quickstart markers")
    return m.group(1)


if __name__ == "__main__":
    if len(sys.argv) != 3:
        raise SystemExit(
            "usage: extract_readme_snippet.py README.md out.py")
    snippet = extract(Path(sys.argv[1]))
    Path(sys.argv[2]).write_text(snippet, encoding="utf-8")
    print(f"wrote {len(snippet.splitlines())} lines -> {sys.argv[2]}")
