#!/usr/bin/env python
"""Render the README's current-numbers table from the committed bench
artifacts (BENCH_kernels.json / BENCH_serving.json / BENCH_drafting.json).

The README embeds the output of this script; regenerate after refreshing
the artifacts:

    python tools/bench_table.py            # print the markdown table
"""

from __future__ import annotations

import json
import sys
from pathlib import Path


def row(metric, value, source):
    return f"| {metric} | {value} | `{source}` |"


def main(root: Path) -> None:
    rows = []

    k = json.loads((root / "BENCH_kernels.json").read_text())
    cuts = [e["hbm_reduction_vs_seed_pct"] for e in k["ws_step"]]
    big = max(k["ws_step"], key=lambda e: e["vocab"])
    rows.append(row(
        "ws_step kernel HBM traffic vs seed kernel",
        f"−{min(cuts):.0f}…−{max(cuts):.0f}% across "
        f"{len(cuts)} shapes (up to {big['vocab']:,} vocab)",
        "BENCH_kernels.json"))

    s = json.loads((root / "BENCH_serving.json").read_text())
    rows.append(row(
        "continuous batching vs one-shot serving",
        f"{s['speedup_requests_per_s']:.1f}× requests/s "
        f"({s['scheduler']['requests_per_s']:.1f} vs "
        f"{s['baseline_one_shot']['requests_per_s']:.1f} req/s)",
        "BENCH_serving.json"))
    st = s.get("streaming")
    if st:
        lat = st["latency_ms"]
        att = st["slo_attainment"]
        rows.append(row(
            "streaming time-to-result (Poisson "
            f"{st['arrival_rate_rps']:.0f} req/s)",
            f"p50/p95/p99 = {lat['p50']:.0f}/{lat['p95']:.0f}/"
            f"{lat['p99']:.0f} ms, SLO attainment {att:.0%} "
            f"@ {st['slo_ms']:.0f} ms",
            "BENCH_serving.json"))
        rows.append(row(
            "streaming first result vs end-of-run",
            f"{st['ttfr_speedup_vs_end_of_run']:.1f}× sooner "
            f"({st['time_to_first_result_s']['p95']:.3f}s vs "
            f"{st['baseline_end_of_run_s']['p95']:.3f}s p95)",
            "BENCH_serving.json"))
    sp = s.get("speculative_streaming")
    if sp:
        on, off = sp["on"], sp["off"]
        rows.append(row(
            "speculative streaming vs same policy w/o speculation",
            f"{sp['speedup_requests_per_s']:.2f}× requests/s "
            f"({on['requests_per_s']:.1f} vs {off['requests_per_s']:.1f}), "
            f"accept rate {on['accept_rate']:.0%} "
            f"({on['accepted']}/{on['eligible']})",
            "BENCH_serving.json"))
    ov = s.get("overload")
    if ov:
        adm = ov["admission"]
        term = ov["terminal"]
        cons = ov["conservation"]
        att = ov["premium_slo_attainment"]
        rows.append(row(
            f"overload ({ov['offered']} offered @ ~2× capacity, "
            f"depth-{ov['queue_depth']} queue)",
            f"completed {term['completed']}, shed {adm['shed']}, "
            f"rejected {adm['rejected']}, cancelled {term['cancelled']}, "
            f"timed out {term['timed_out']}, failed {term['failed']} — "
            f"conservation {'OK' if cons['balanced'] else 'BROKEN'}",
            "BENCH_serving.json"))
        be_p99 = ov.get("best_effort_p99_ms")
        rows.append(row(
            "overload per-class degradation",
            f"premium attainment "
            f"{'-' if att is None else format(att, '.0%')}, "
            f"best_effort p99 "
            f"{'-' if be_p99 is None else f'{be_p99:.0f} ms'}, "
            f"dispatch retries {ov['dispatch']['retries']} "
            f"(injected faults, exp backoff)",
            "BENCH_serving.json"))
    tr = s.get("tracing_overhead")
    if tr:
        rows.append(row(
            "span-tracing overhead on streaming serve",
            f"{tr['throughput_ratio_on_vs_off']:.2f}× throughput with a "
            f"live SpanTracer ring vs NullTracer "
            f"({tr['on']['spans_emitted']} spans recorded; gate ≥ 0.9×)",
            "BENCH_serving.json"))

    d = json.loads((root / "BENCH_drafting.json").read_text())
    adaptive = d["adaptive_t0"]["mean_request_nfe"]
    fixed = d["fixed_worst_tier_t0"]["mean_request_nfe"]
    rows.append(row(
        "measured draft cost (AR KV-cache engine)",
        f"{d['draft_cost']['cost_ratio']:.3f} of one backbone NFE",
        "BENCH_drafting.json"))
    rows.append(row(
        "adaptive per-request t0 vs fixed worst-tier t0",
        f"{adaptive:.1f} vs {fixed:.1f} mean NFE/request "
        f"(−{100 * (1 - adaptive / fixed):.0f}%)",
        "BENCH_drafting.json"))
    bs = d.get("bandit_speculative")
    if bs:
        rows.append(row(
            "bandit t0 + speculative accept vs calibrated lookup",
            f"{bs['mean_request_nfe']:.1f} vs {adaptive:.1f} mean "
            f"NFE/request "
            f"(−{d['speculative_nfe_reduction_pct']:.0f}%), accept rate "
            f"{bs['accept_rate']:.0%} ({bs['accepted']}/{bs['eligible']})",
            "BENCH_drafting.json"))
    dt = d.get("distilled")
    if dt:
        rows.append(row(
            "distilled tier (self-distilled few-step head + quality floor)",
            f"{dt['served']}/{dt['requests']} served at NFE={dt['nfe']} "
            f"({dt['fallbacks']} quality-floor fallbacks, floor "
            f"{dt['gate_score']:.2f}; blended stream mean "
            f"{dt['mean_stream_nfe']:.1f} NFE)",
            "BENCH_drafting.json"))

    print("| metric | current number (CPU smoke run) | source |")
    print("|---|---|---|")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main(Path(sys.argv[1] if len(sys.argv) > 1 else "."))
