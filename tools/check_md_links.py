#!/usr/bin/env python
"""Markdown link checker: every relative link in every tracked *.md must
resolve to a real file, so the README's subsystem map and the
cross-references between subsystem docs cannot rot.

Checks ``[text](target)`` links, skipping absolute URLs
(http/https/mailto) and pure in-page anchors (``#...``). Anchors on
file links (``path.md#section``) are checked for file existence only.

Run:  python tools/check_md_links.py [root]        (exit 1 on breakage)
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "node_modules"}
SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def md_files(root: Path):
    for path in sorted(root.rglob("*.md")):
        if not any(part in SKIP_DIRS for part in path.parts):
            yield path


def check(root: Path) -> int:
    broken = []
    n_links = 0
    for md in md_files(root):
        text = md.read_text(encoding="utf-8")
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(SKIP_SCHEMES) or target.startswith("#"):
                continue
            n_links += 1
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = (md.parent / rel).resolve()
            if not resolved.exists():
                line = text[:m.start()].count("\n") + 1
                broken.append((md.relative_to(root), line, target))
    for md, line, target in broken:
        print(f"BROKEN {md}:{line}: ({target})")
    print(f"checked {n_links} relative links in "
          f"{sum(1 for _ in md_files(root))} markdown files: "
          f"{len(broken)} broken")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(check(Path(sys.argv[1] if len(sys.argv) > 1 else ".")))
